"""Flight-recorder tracing (DESIGN.md §12).

A preallocated ring-buffer event log for the fused serving stack:

  * **Fixed capacity, allocation-free on the hot path.** Events live in
    preallocated numpy arrays (timestamp, duration, interned name id,
    interned track id, up to four float64 args); recording an event is a
    handful of scalar stores. When the ring is full the oldest events are
    overwritten — flight-recorder semantics — and ``dropped`` counts the
    casualties, so a reader always knows whether the window is complete.
  * **One clock domain.** Every timestamp is host ``time.perf_counter()``
    (monotonic, sub-microsecond). There are deliberately NO in-jit
    timestamps: a device-side clock read would force a host sync (or a new
    output crossing the jit boundary), breaking the megastep's
    one-dispatch / int32-only-return contract — see DESIGN.md §12.
  * **Spans and instants.** A span is recorded at its END as a Chrome
    "complete" event (begin timestamp captured by the caller via
    ``now()``); an instant is a point event. Interning (``name()``,
    ``track()``) happens once at instrumentation-setup time, so the hot
    path never hashes strings.
  * **Exporters.** ``export_chrome`` writes Chrome trace-event JSON that
    loads in Perfetto / chrome://tracing — tracks are (pid, tid) pairs
    derived from the registered track groups (one process row per group:
    engine, engine rows, sessions, mlfq) — and ``export_ndjson`` writes
    newline-delimited JSON for ad-hoc tooling. ``validate_chrome`` is the
    schema check CI runs against the exported artifact.

A disabled recorder (``TraceConfig(enabled=False)``, the default) keeps
the full API but drops every event before touching the buffer, so
instrumented code needs no branches beyond the recorder's own.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["TraceConfig", "FlightRecorder", "validate_chrome"]

_INSTANT, _SPAN = 0, 1
_MAX_ARGS = 4


@dataclasses.dataclass
class TraceConfig:
    """Gates the flight recorder. Off by default; when on, the overhead
    contract is <= 2% tokens/sec on the sched_live smoke (CI-gated via
    BENCH_obs.json)."""
    enabled: bool = False
    capacity: int = 1 << 16      # events; ~3 MB of ring at 44 B/event

    def __post_init__(self):
        if self.capacity < 16:
            raise ValueError(
                f"trace capacity {self.capacity} too small: the ring must "
                "hold at least 16 events (one megastep's worth with "
                "headroom) to be a usable flight recorder")
        if self.capacity > (1 << 24):
            raise ValueError(
                f"trace capacity {self.capacity} too large: the ring is "
                "preallocated host memory; cap it at 2^24 events (~700 MB)")


class FlightRecorder:
    """Preallocated ring-buffer trace log with drop-oldest semantics."""

    def __init__(self, config: Optional[TraceConfig] = None):
        self.config = config or TraceConfig()
        self.enabled = self.config.enabled
        n = self.config.capacity
        self.capacity = n
        # Hot-path ring: parallel preallocated Python lists. A plain list
        # slot store is ~2.5x cheaper than a numpy scalar assignment, and
        # every stored value is a reference the caller already holds
        # (floats from perf_counter()/args, interned small ints) — so the
        # emit path stays allocation-free. numpy views are rebuilt only at
        # export time in events().
        self._ts = [0.0] * n
        self._dur = [0.0] * n
        self._ph = [0] * n
        self._name = [0] * n
        self._track = [0] * n
        self._a0 = [0.0] * n
        self._a1 = [0.0] * n
        self._a2 = [0.0] * n
        self._a3 = [0.0] * n
        self._total = 0                       # events ever recorded
        # interning tables: id 0 is reserved for "unnamed"/"main" so a
        # disabled recorder can hand out 0 without registering anything
        self._names: List[Tuple[str, Tuple[str, ...]]] = [("event", ())]
        self._name_ids: Dict[str, int] = {"event": 0}
        self._tracks: List[Tuple[str, str]] = [("main", "main")]
        self._track_ids: Dict[Tuple[str, str], int] = {("main", "main"): 0}
        self._t0 = time.perf_counter()

    # ------------------------------------------------------ interning
    def name(self, label: str, arg_labels: Sequence[str] = ()) -> int:
        """Intern an event name (+ the labels its numeric args carry in
        exports). Call once at instrumentation-setup time."""
        nid = self._name_ids.get(label)
        if nid is None:
            nid = len(self._names)
            self._names.append((label, tuple(arg_labels)))
            self._name_ids[label] = nid
        return nid

    def track(self, label: str, group: str = "main") -> int:
        """Intern a display track. ``group`` becomes the Perfetto process
        row (pid); each track in it a thread row (tid)."""
        key = (group, label)
        tid = self._track_ids.get(key)
        if tid is None:
            tid = len(self._tracks)
            self._tracks.append((label, group))
            self._track_ids[key] = tid
        return tid

    # ------------------------------------------------------- recording
    @staticmethod
    def now() -> float:
        return time.perf_counter()

    def _emit(self, ph: int, name_id: int, track_id: int, ts: float,
              dur: float, a0: float, a1: float, a2: float, a3: float):
        i = self._total % self.capacity
        self._ts[i] = ts
        self._dur[i] = dur
        self._ph[i] = ph
        self._name[i] = name_id
        self._track[i] = track_id
        self._a0[i] = a0
        self._a1[i] = a1
        self._a2[i] = a2
        self._a3[i] = a3
        self._total += 1

    # instant/complete inline the _emit body: one less call frame per
    # event on the per-token hot path (the default-arg _clock binding
    # skips the time-module attribute lookup each call)
    def instant(self, name_id: int, track_id: int, a0: float = 0.0,
                a1: float = 0.0, a2: float = 0.0, a3: float = 0.0,
                _clock=time.perf_counter):
        if not self.enabled:
            return
        i = self._total % self.capacity
        self._ts[i] = _clock()
        self._dur[i] = 0.0
        self._ph[i] = _INSTANT
        self._name[i] = name_id
        self._track[i] = track_id
        self._a0[i] = a0
        self._a1[i] = a1
        self._a2[i] = a2
        self._a3[i] = a3
        self._total += 1

    def complete(self, name_id: int, track_id: int, t0: float,
                 a0: float = 0.0, a1: float = 0.0, a2: float = 0.0,
                 a3: float = 0.0, _clock=time.perf_counter):
        """Record a span that began at ``t0`` (from ``now()``) and ends
        now."""
        if not self.enabled:
            return
        dur = _clock() - t0
        i = self._total % self.capacity
        self._ts[i] = t0
        self._dur[i] = dur if dur > 0.0 else 0.0
        self._ph[i] = _SPAN
        self._name[i] = name_id
        self._track[i] = track_id
        self._a0[i] = a0
        self._a1[i] = a1
        self._a2[i] = a2
        self._a3[i] = a3
        self._total += 1

    def span(self, label: str, track_label: str = "main",
             group: str = "main", **args):
        """Convenience context-manager span for non-hot-path callers (hot
        paths pre-intern and call ``complete`` directly)."""
        return _Span(self, self.name(label, tuple(args)),
                     self.track(track_label, group),
                     tuple(float(v) for v in args.values()))

    # ------------------------------------------------------ accounting
    @property
    def recorded(self) -> int:
        """Events currently held in the ring."""
        return min(self._total, self.capacity)

    @property
    def total(self) -> int:
        return self._total

    @property
    def dropped(self) -> int:
        """Events overwritten by drop-oldest wraparound."""
        return max(0, self._total - self.capacity)

    def reset(self):
        self._total = 0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------- exporting
    def events(self) -> List[dict]:
        """Decode the ring into dicts sorted by timestamp (the ring is not
        time-ordered after wraparound). Allocation-heavy; export-time
        only."""
        n = self.recorded
        if n == 0:
            return []
        if self._total <= self.capacity:
            idx = list(range(n))
        else:                       # ring wrapped: oldest is at write head
            head = self._total % self.capacity
            idx = list(range(head, self.capacity)) + list(range(head))
        idx.sort(key=self._ts.__getitem__)       # stable, like the ring
        av = (self._a0, self._a1, self._a2, self._a3)
        out = []
        for i in idx:
            name, labels = self._names[self._name[i]]
            tlabel, group = self._tracks[self._track[i]]
            args = {lab: av[j][i] for j, lab in enumerate(labels)}
            out.append({
                "name": name, "track": tlabel, "group": group,
                "ph": "X" if self._ph[i] == _SPAN else "i",
                "ts": self._ts[i], "dur": self._dur[i],
                "args": args,
            })
        return out

    def _pids_tids(self) -> Dict[int, Tuple[int, int]]:
        """track id -> (pid, tid): pid per group, tid per track within."""
        groups: Dict[str, int] = {}
        per_group: Dict[str, int] = {}
        mapping = {}
        for tid_, (label, group) in enumerate(self._tracks):
            pid = groups.setdefault(group, len(groups) + 1)
            per_group[group] = per_group.get(group, 0) + 1
            mapping[tid_] = (pid, per_group[group])
        return mapping

    def chrome(self) -> dict:
        """Chrome trace-event JSON object (ts/dur in microseconds,
        relative to recorder start; metadata rows name each group/track)."""
        mapping = self._pids_tids()
        evs: List[dict] = []
        seen_groups = set()
        for tid_, (label, group) in enumerate(self._tracks):
            pid, tid = mapping[tid_]
            if group not in seen_groups:
                seen_groups.add(group)
                evs.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": group}})
            evs.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": label}})
        for e in self.events():
            pid, tid = mapping[self._track_ids[(e["group"], e["track"])]]
            ev = {"name": e["name"], "ph": e["ph"], "pid": pid, "tid": tid,
                  "ts": (e["ts"] - self._t0) * 1e6, "cat": e["group"],
                  "args": e["args"]}
            if e["ph"] == "X":
                ev["dur"] = e["dur"] * 1e6
            else:
                ev["s"] = "t"
            evs.append(ev)
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "clock_domain": "host perf_counter"}}

    def export_chrome(self, path: str):
        with open(path, "w") as f:
            json.dump(self.chrome(), f)

    def export_ndjson(self, path: str):
        with open(path, "w") as f:
            for e in self.events():
                f.write(json.dumps(e) + "\n")


class _Span:
    __slots__ = ("rec", "name_id", "track_id", "args", "t0")

    def __init__(self, rec: FlightRecorder, name_id: int, track_id: int,
                 args: Tuple[float, ...]):
        self.rec, self.name_id, self.track_id = rec, name_id, track_id
        self.args = args

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        a = self.args + (0.0,) * (_MAX_ARGS - len(self.args))
        self.rec.complete(self.name_id, self.track_id, self.t0, *a[:4])
        return False


def validate_chrome(obj: dict) -> List[str]:
    """Schema check for an exported Chrome trace: returns a list of
    problems (empty = valid). CI runs this against the sched_live trace
    artifact."""
    problems = []
    evs = obj.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    last_ts = -np.inf
    for i, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            problems.append(f"event {i}: missing name")
        if not isinstance(e.get("pid"), int) \
                or not isinstance(e.get("tid"), int):
            problems.append(f"event {i}: pid/tid must be ints")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: non-numeric ts")
            continue
        if ts < last_ts:
            problems.append(f"event {i}: ts {ts} < previous {last_ts} "
                            "(export must be time-sorted)")
        last_ts = ts
        if ph == "X" and (not isinstance(e.get("dur"), (int, float))
                          or e["dur"] < 0):
            problems.append(f"event {i}: X event needs dur >= 0")
    return problems

"""Observability subsystem: flight-recorder tracing + unified metrics
(DESIGN.md §12).

``Observability`` bundles the two halves the serving stack shares:

  * ``metrics`` — a ``MetricsRegistry`` that is ALWAYS active (counters
    and fixed-bucket histograms are cheap enough for the hot path) and is
    the single source every stats surface reads from: the engine's
    ``step_stats()``/``kv_stats()``, the middleware's ``ResourceMonitor``
    snapshot, and every BENCH json — so they can never disagree.
  * ``recorder`` — a ``FlightRecorder`` ring-buffer event log, gated OFF
    by default by ``TraceConfig`` (overhead contract: <= 2% tokens/sec
    when on, CI-gated).

One ``Observability`` per serving stack: build it once and pass it to the
engine and ``AgentRM`` (the middleware auto-adopts its backend's engine
``obs`` when none is given, so the fused stack shares one clock, one ring
and one registry by default).
"""
from repro.obs.metrics import (LATENCY_BUCKETS_S, Counter, Gauge, Histogram,
                               MetricsRegistry, log_buckets)
from repro.obs.trace import FlightRecorder, TraceConfig, validate_chrome

__all__ = ["Observability", "TraceConfig", "FlightRecorder",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "log_buckets", "LATENCY_BUCKETS_S", "validate_chrome"]


class Observability:
    """Shared tracing + metrics context for one serving stack."""

    def __init__(self, trace: TraceConfig = None,
                 metrics: MetricsRegistry = None):
        self.trace_config = trace or TraceConfig()
        self.recorder = FlightRecorder(self.trace_config)
        self.metrics = metrics or MetricsRegistry()

    @property
    def tracing(self) -> bool:
        return self.recorder.enabled

"""Unified metrics registry (DESIGN.md §12).

One process-wide-ish registry per serving stack instance absorbs the
counters that used to live scattered across ``ResourceMonitor``,
``PagedInferenceEngine.step_stats()``, ``kv_stats()``, and per-benchmark
Python lists. Three metric kinds, all bounded-memory by construction:

  * ``Counter`` — monotonic accumulator (tokens, dispatches, reaps).
  * ``Gauge``   — last-write-wins level (queue depth, blocks in use).
  * ``Histogram`` — fixed log-spaced buckets for latency-shaped data
    (TTFT / ITL / step time). Quantiles are estimated from the bucket
    cumulative counts with linear interpolation inside the containing
    bucket, so the relative error is bounded by the bucket ratio
    (``10**(1/per_decade) - 1``) no matter how many samples stream in.
    An optional bounded reservoir (Vitter's Algorithm R) keeps up to
    ``reservoir`` raw samples: while nothing has been evicted the
    quantile is exact — which is what the benchmarks' small runs want —
    and once the stream outgrows it the histogram estimate takes over.

This replaces the engine's unbounded per-token ``ttft_s``/``itl_s``
Python lists — the exact "unbounded memory growth" failure mode the
paper catalogs for long-lived agent processes.

Writers are expected to be serialized by their caller's lock (the engine
runs under the backend lock, the middleware under its own); the registry
lock only guards metric creation.
"""
from __future__ import annotations

import json
import math
import random
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "log_buckets", "LATENCY_BUCKETS_S"]


def log_buckets(lo: float, hi: float, per_decade: int = 12
                ) -> Tuple[float, ...]:
    """Geometric bucket upper bounds covering [lo, hi]: ``per_decade``
    buckets per factor of 10. Memory is fixed at construction; relative
    quantile error is bounded by ``10**(1/per_decade) - 1``."""
    assert 0 < lo < hi and per_decade > 0
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


# default latency buckets: 10 µs .. 100 s at 12 per decade (85 buckets) —
# covers a Pallas kernel dispatch through a CI-box compile stall, with
# ~21% worst-case relative quantile error from the buckets alone
LATENCY_BUCKETS_S = log_buckets(1e-5, 100.0, 12)


class Counter:
    """Monotonic accumulator. ``set`` exists only so benchmarks can zero a
    measurement window; live instrumentation must use ``inc``."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0):
        self.value += n

    def set(self, v: float):
        self.value = float(v)

    def reset(self):
        self.value = 0.0

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge(Counter):
    kind = "gauge"


class Histogram:
    """Fixed-bucket histogram with bounded-error quantiles and an optional
    bounded exact-sample reservoir."""

    kind = "histogram"

    # ring capacity for the recency window every histogram keeps (see
    # ``windowed_quantile``) — bounded regardless of stream length
    WINDOW_CAP = 512

    def __init__(self, name: str, bounds: Sequence[float] = LATENCY_BUCKETS_S,
                 reservoir: int = 0, seed: int = 0):
        self.name = name
        self.bounds = np.asarray(bounds, np.float64)
        assert self.bounds.ndim == 1 and len(self.bounds) >= 2 \
            and bool(np.all(np.diff(self.bounds) > 0)), \
            f"histogram {name}: bounds must be increasing"
        # counts[i] holds observations v <= bounds[i]; the final slot is
        # the overflow bucket (v > bounds[-1])
        self.counts = np.zeros(len(self.bounds) + 1, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._res_cap = int(reservoir)
        self._res: List[float] = []
        self._rng = random.Random(seed)
        # (t, v) recency ring: lifetime buckets answer "how has this run
        # gone", the ring answers "how is it going RIGHT NOW" — the SLO
        # autopilot's control signal. Timestamps are host perf_counter
        # (the obs clock domain), overridable for virtual-clock callers.
        self._win: deque = deque(maxlen=self.WINDOW_CAP)

    def observe(self, v: float, now: Optional[float] = None):
        self.counts[int(np.searchsorted(self.bounds, v, side="left"))] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self._win.append((time.perf_counter() if now is None else now, v))
        if self._res_cap:
            if len(self._res) < self._res_cap:
                self._res.append(v)
            else:
                j = self._rng.randrange(self.count)
                if j < self._res_cap:
                    self._res[j] = v

    @property
    def samples(self) -> List[float]:
        """Bounded reservoir contents (all observations, while the stream
        fits; a uniform sample once it doesn't)."""
        return list(self._res)

    @property
    def exact(self) -> bool:
        """True while the reservoir still holds every observation."""
        return bool(self._res_cap) and self.count <= self._res_cap

    def quantile(self, q: float) -> float:
        """q-quantile: exact from the reservoir while nothing has been
        evicted, else interpolated from the buckets (error bounded by the
        bucket ratio)."""
        if self.count == 0:
            return 0.0
        if self.exact:
            return float(np.percentile(np.asarray(self._res), 100.0 * q))
        target = max(q * self.count, 1.0)
        cum = np.cumsum(self.counts)
        idx = int(np.searchsorted(cum, target, side="left"))
        lower = float(self.bounds[idx - 1]) if idx > 0 else \
            min(self.min, float(self.bounds[0]))
        upper = float(self.bounds[idx]) if idx < len(self.bounds) else self.max
        prev = int(cum[idx - 1]) if idx > 0 else 0
        in_bucket = int(cum[idx]) - prev
        frac = (target - prev) / max(in_bucket, 1)
        return float(min(max(lower + frac * (upper - lower), self.min),
                         self.max))

    def windowed_count(self, horizon_s: float,
                       now: Optional[float] = None) -> int:
        """Observations recorded within the last ``horizon_s`` seconds
        (clamped to the ring capacity — a firehose stream ages out)."""
        t = time.perf_counter() if now is None else now
        return sum(1 for ts, _ in self._win if ts >= t - horizon_s)

    def windowed_quantile(self, q: float, horizon_s: float,
                          now: Optional[float] = None) -> Optional[float]:
        """q-quantile over ONLY the observations of the last ``horizon_s``
        seconds. Returns None when the window is empty — "no signal",
        which a feedback controller must treat as hold-not-act (an idle
        engine's stale lifetime p95 would otherwise trip it forever)."""
        t = time.perf_counter() if now is None else now
        recent = [v for ts, v in self._win if ts >= t - horizon_s]
        if not recent:
            return None
        return float(np.percentile(np.asarray(recent), 100.0 * q))

    def reset(self):
        self.counts[:] = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._res.clear()
        self._win.clear()

    def snapshot(self) -> dict:
        return {
            "type": self.kind,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "exact": self.exact,
        }


class MetricsRegistry:
    """Name -> metric store with get-or-create accessors, a single
    ``snapshot()`` for benchmarks/JSON dumps, and a Prometheus-style text
    exposition. ``reset()`` zeroes every metric — benchmarks call it after
    warmup so every reported column describes the same window."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, factory, kind: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif m.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"not {kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, lambda: Counter(name), "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, lambda: Gauge(name), "gauge")

    def histogram(self, name: str,
                  bounds: Sequence[float] = LATENCY_BUCKETS_S,
                  reservoir: int = 0, seed: int = 0) -> Histogram:
        return self._get(
            name, lambda: Histogram(name, bounds, reservoir, seed),
            "histogram")

    def get(self, name: str) -> Optional[object]:
        return self._metrics.get(name)

    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def reset(self):
        with self._lock:
            for m in self._metrics.values():
                m.reset()

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())}

    def render_text(self) -> str:
        """Prometheus-ish exposition: one block per metric, histogram
        quantiles as pre-baked lines (this is a dump format, not a live
        scrape endpoint — no _bucket series needed)."""
        out = []
        for name, snap in self.snapshot().items():
            flat = name.replace(".", "_").replace("-", "_")
            out.append(f"# TYPE {flat} {snap['type']}")
            if snap["type"] == "histogram":
                for k in ("count", "sum", "min", "max", "p50", "p95", "p99"):
                    out.append(f"{flat}_{k} {snap[k]:.9g}")
            else:
                out.append(f"{flat} {snap['value']:.9g}")
        return "\n".join(out) + "\n"

    def dump_json(self, path: str):
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)
